"""Architecture / shape / sharding configuration system.

Every assigned architecture gets a ``configs/<id>.py`` exporting ``CONFIG``
(exact published dimensions) and ``SMOKE_CONFIG`` (reduced same-family
config for CPU tests).  Input shapes come from the shared SHAPES registry;
``launch/dryrun.py`` iterates (arch x shape x mesh) cells.

Sharding uses MaxText-style logical axes: parameters and activations are
annotated with logical names, and :func:`logical_to_mesh` maps them to mesh
axes per run mode.  Vocab sizes are padded to a multiple of 256 (standard
Megatron-style padding) so the "model" axis always divides the embedding.
"""

from __future__ import annotations

import dataclasses
import importlib
import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Mapping, Optional, Sequence, Tuple

VOCAB_PAD = 256


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    # ---- attention pattern ----
    sliding_window: Optional[int] = None
    local_global_ratio: int = 0       # gemma3: 5 (5 local : 1 global)
    qkv_bias: bool = False
    # ---- ffn ----
    ffn_act: str = "swiglu"           # swiglu | geglu
    # ---- MoE ----
    moe: bool = False
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # ---- SSM / hybrid ----
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0               # zamba2: shared attn block cadence
    # ---- xLSTM ----
    xlstm_slstm_every: int = 0        # 1-in-N blocks are sLSTM
    # ---- encoder-decoder ----
    encoder_layers: int = 0
    # ---- frontend stub ----
    frontend: Optional[str] = None    # vision | audio
    frontend_tokens: int = 256        # patches / frames provided pre-embedded
    # ---- misc ----
    tie_embeddings: bool = True
    rope_theta: float = 1e4
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    remat: str = "none"               # none | dots | full
    moe_impl: str = "sorted"          # sorted (production) | dense (oracle)
    note: str = ""

    # ------------------------------------------------------------- derived
    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic serving path exists (SSM / hybrid / sliding-window)."""
        return (
            self.family in ("ssm", "hybrid")
            or (self.sliding_window is not None and self.local_global_ratio > 0)
        )

    def param_count(self) -> int:
        """Approximate parameter count N (for MODEL_FLOPS = 6*N*D)."""
        d, L = self.d_model, self.n_layers
        dh = self.head_dim_
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (self.n_heads * dh) * d
        if self.moe:
            ff = 3 * d * self.d_ff * self.n_experts
        elif self.d_ff > 0:
            mult = 3 if self.ffn_act in ("swiglu", "geglu") else 2
            ff = mult * d * self.d_ff
        else:
            ff = 0
        if self.family == "ssm":       # xLSTM-style blocks
            inner = 2 * d
            block = 2 * d * inner + inner * d + inner * 3  # projections+gates
            body = L * block
        elif self.family == "hybrid":  # mamba2 blocks + shared attn
            inner = self.ssm_expand * d
            mamba = 2 * d * inner + inner * d + inner * (2 * self.ssm_state)
            n_attn_uses = L // max(1, self.attn_every)
            body = L * mamba + (attn + 3 * d * self.d_ff)  # one shared block
            del n_attn_uses
        else:
            body = L * (attn + ff)
        emb = self.padded_vocab * d
        enc = self.encoder_layers * (attn + ff)
        return body + emb + enc

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed experts)."""
        if not self.moe:
            return self.param_count()
        d, L = self.d_model, self.n_layers
        dh = self.head_dim_
        attn = d * (self.n_heads * dh) + 2 * d * (self.n_kv_heads * dh) + (self.n_heads * dh) * d
        ff_active = 3 * d * self.d_ff * self.top_k
        return L * (attn + ff_active) + self.padded_vocab * d


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS: List[str] = [
    "internvl2_26b",
    "seamless_m4t_large_v2",
    "gemma3_12b",
    "deepseek_67b",
    "qwen2_1_5b",
    "gemma_7b",
    "granite_moe_1b_a400m",
    "llama4_scout_17b_a16e",
    "zamba2_2_7b",
    "xlstm_350m",
]


def get_config(arch: str, smoke: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.SMOKE_CONFIG if smoke else mod.CONFIG


def cell_is_skipped(cfg: ArchConfig, shape: ShapeConfig) -> Optional[str]:
    """Return a skip reason for (arch, shape), or None if the cell runs.

    ``long_500k`` requires a sub-quadratic serving path; pure full-attention
    archs skip it (recorded in DESIGN.md §5 and EXPERIMENTS.md).
    """
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return "long_500k skipped: pure full-attention architecture"
    return None


# ---------------------------------------------------------------------------
# Logical-axis sharding rules
# ---------------------------------------------------------------------------

def mesh_rules(mode: str, mesh_axis_names: Sequence[str]) -> Dict[str, Any]:
    """Logical axis -> mesh axes, per run mode.

    ``batch`` spreads over the pure-DP axes ("pod","data"); ``kv_seq`` is the
    decode KV-cache sequence dim: sharded over "model" so huge caches fit
    (flash-decode style — XLA inserts the partial-softmax all-reduce), except
    in long_500k where batch=1 cannot use "data", so the cache spreads over
    both. Embed/mlp/heads follow standard Megatron TP.
    """
    has_pod = "pod" in mesh_axis_names
    dp: Any = ("pod", "data") if has_pod else ("data",)
    # FSDP (train): weight OUTPUT dims shard over ("model","data") jointly.
    # Sharding the contraction (d_model) dim over the batch axis made GSPMD
    # reshard full-batch activations (partial-contraction strategy: §Perf
    # hillclimb B measured 1.1TB/step of f32 activation all-reduces on
    # deepseek); sharding the output dim instead leaves only the cheap
    # weight all-gather over "data" — canonical FSDP semantics.
    fsdp = ("model", "data") if mode == "train" else "model"
    rules: Dict[str, Any] = {
        "batch": dp,
        "vocab": "model",
        "embed": None,
        "layers": None,
        "heads": "model",
        "kv_heads": None,     # replicated unless divisible — set per arch
        "q_dim": fsdp,        # flattened H*dh projections
        "mlp": fsdp,
        "experts": "model",
        "expert_cap": None,
        "seq": None,
        "kv_seq": None,
        "state": None,
        "conv": None,
    }
    if mode == "decode":
        rules["kv_seq"] = "model"
    if mode == "decode_long":
        # batch=1: KV pages spread over data AND model
        rules["batch"] = None
        rules["kv_seq"] = ("data", "model")
        rules["heads"] = "model"
    return rules


def logical_to_spec(logical: Sequence[Optional[str]], rules: Mapping[str, Any]):
    """Translate logical axis names to a jax PartitionSpec.

    A mesh axis may appear only once per tensor: when a later logical axis
    requests an already-used mesh axis, the used *component* is dropped
    (e.g. MoE (experts->model, mlp->(model,data)) yields (model, ..., data)).
    """
    from jax.sharding import PartitionSpec as P

    out = []
    used: set = set()
    for name in logical:
        axis = rules.get(name) if name is not None else None
        if axis is not None:
            flat = (axis,) if isinstance(axis, str) else tuple(axis)
            keep = tuple(a for a in flat if a not in used)
            used.update(keep)
            axis = None if not keep else (keep[0] if len(keep) == 1 else keep)
        out.append(axis)
    return P(*out)
