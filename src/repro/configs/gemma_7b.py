"""Gemma-7B [arXiv:2403.08295]: GeGLU, head_dim=256, MHA (kv=16)."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma_7b", family="dense",
    n_layers=28, d_model=3072, n_heads=16, n_kv_heads=16,
    d_ff=24576, vocab_size=256000, head_dim=256,
    ffn_act="geglu", rope_theta=1e4, remat="dots",
    note="long_500k SKIPPED: pure full attention",
)

SMOKE_CONFIG = ArchConfig(
    name="gemma_7b_smoke", family="dense",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=192, vocab_size=512, head_dim=32, ffn_act="geglu",
)
