"""InternVL2-26B [arXiv:2404.16821]: InternViT frontend (stub) + InternLM2-20B
backbone. Backbone dims per assignment; vision patches arrive pre-embedded."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2_26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=16384, vocab_size=92553,
    ffn_act="swiglu", rope_theta=1e6, frontend="vision", frontend_tokens=256,
    tie_embeddings=False, remat="dots",
    note="vision frontend is a stub: input_specs provides patch embeddings",
)

SMOKE_CONFIG = ArchConfig(
    name="internvl2_26b_smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=512,
    ffn_act="swiglu", frontend="vision", frontend_tokens=8,
    tie_embeddings=False,
)
