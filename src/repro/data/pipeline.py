"""Multi-stream training data pipeline with straggler mitigation.

Streams (train replicas, eval, the catch-up reader of a restarted node) pull
fixed-shape (B, T) batches by walking dataset pages through the shared
:class:`HostPageCache`.  Two paper-derived mechanisms:

* **Starved-stream priority** (QueryRelevance reused): the scheduler hands
  the next batch-build slot to the stream furthest behind its expected
  position — a restarted/straggling data-parallel reader catches up first
  because its pages are the soonest-consumed (PBM keeps them hot).
* **Work stealing**: `steal_from` lets a healthy reader take over a failed
  reader's remaining page range; the cache's registered plan is swapped
  accordingly (unregister + register), so eviction priorities follow.

Deterministic restart: a stream's position is (epoch, shard_idx, page,
offset) — `state_dict`/`load_state_dict` round-trips it (checkpointable).
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional, Tuple

import numpy as np

from .cache import HostPageCache
from .dataset import PAGE_TOKENS, DatasetSpec


@dataclass
class StreamState:
    stream_id: int
    shard_order: List[int]
    shard_idx: int = 0
    page: int = 0
    offset: int = 0
    tokens_consumed: int = 0
    epoch: int = 0

    def position(self) -> Tuple[int, int, int, int]:
        return (self.epoch, self.shard_idx, self.page, self.offset)


class DataStream:
    """One sequential reader producing (B, T) token batches."""

    def __init__(
        self,
        cache: HostPageCache,
        shard_order: List[int],
        batch: int,
        seq_len: int,
        name: str = "train",
    ) -> None:
        self.cache = cache
        self.batch = batch
        self.seq_len = seq_len
        self.name = name
        sid = cache.register_stream(shard_order)
        self.state = StreamState(stream_id=sid, shard_order=list(shard_order))
        self._buf = np.empty((0,), np.int32)
        self._skip = 0  # tokens to drop after a mid-page restore

    # ------------------------------------------------------------------ io
    def _advance_page(self) -> np.ndarray:
        st = self.state
        spec = self.cache.spec
        shard = st.shard_order[st.shard_idx]
        toks = self.cache.get_page(st.stream_id, shard, st.page)
        if self._skip:
            toks = toks[self._skip:]
            self._skip = 0
        st.page += 1
        if st.page >= spec.pages_per_shard:
            st.page = 0
            st.shard_idx += 1
            if st.shard_idx >= len(st.shard_order):
                st.shard_idx = 0
                st.epoch += 1  # re-scan: a new "query" over the same table
        return toks

    def next_batch(self) -> np.ndarray:
        need = self.batch * self.seq_len
        while self._buf.size < need:
            self._buf = np.concatenate([self._buf, self._advance_page()])
        out = self._buf[:need].reshape(self.batch, self.seq_len)
        self._buf = self._buf[need:]
        self.state.tokens_consumed += need
        self.cache.report_position(self.state.stream_id, self.state.tokens_consumed)
        return out

    # ------------------------------------------------- checkpoint/restart
    def state_dict(self) -> Dict:
        return dataclasses.asdict(self.state)

    def load_state_dict(self, d: Dict) -> None:
        """Exact mid-page resume: the canonical position is tokens_consumed;
        (shard, page, offset) are recomputed from it so a restore lands on
        the precise next token even though the in-memory read buffer of the
        failed reader is gone."""
        from .dataset import PAGE_TOKENS

        sid = self.state.stream_id
        st = StreamState(**{**d, "stream_id": sid})
        pp = self.cache.spec.pages_per_shard
        n_order = max(1, len(st.shard_order))
        pages_done = st.tokens_consumed // PAGE_TOKENS
        st.epoch = pages_done // (pp * n_order)
        rem = pages_done % (pp * n_order)
        st.shard_idx = rem // pp
        st.page = rem % pp
        st.offset = st.tokens_consumed % PAGE_TOKENS
        self.state = st
        self._buf = np.empty((0,), np.int32)
        self._skip = st.offset


class MultiStreamLoader:
    """Schedules several streams over one shared cache (straggler-aware)."""

    def __init__(self, cache: HostPageCache):
        self.cache = cache
        self.streams: Dict[str, DataStream] = {}
        self._expected: Dict[str, int] = {}

    def add_stream(self, stream: DataStream) -> None:
        self.streams[stream.name] = stream
        self._expected[stream.name] = 0

    def next_round(self) -> Dict[str, np.ndarray]:
        """One batch per stream; most-behind (starved) stream served first."""
        order = sorted(
            self.streams,
            key=lambda n: self.streams[n].state.tokens_consumed - self._expected[n],
        )
        out = {}
        for name in order:
            out[name] = self.streams[name].next_batch()
            self._expected[name] += self.streams[name].batch * self.streams[name].seq_len
        return out

    def steal_from(self, failed: str, healthy: str) -> None:
        """Work stealing: ``healthy`` adopts ``failed``'s remaining range."""
        f = self.streams.pop(failed)
        self.cache.unregister_stream(f.state.stream_id)
        h = self.streams[healthy]
        # extend the healthy stream's shard order with the failed remainder
        remaining = f.state.shard_order[f.state.shard_idx:]
        h.state.shard_order.extend(remaining)
        self._expected.pop(failed, None)
