from .dataset import PAGE_TOKENS, DatasetSpec, generate_page, make_dataset_db
from .cache import HostPageCache
from .pipeline import DataStream, MultiStreamLoader

__all__ = [
    "DataStream", "DatasetSpec", "HostPageCache", "MultiStreamLoader",
    "PAGE_TOKENS", "generate_page", "make_dataset_db",
]
