"""Host page cache for the data pipeline, managed by the paper's policies.

Concurrent training/eval streams disclose their page access plans up front
(RegisterScan), report positions as they consume, and the cache evicts by
PBM / LRU / OPT — a live (wall-clock-driven) deployment of ``repro.core``,
not a simulation.  The metric mirrors the paper: bytes re-read from slow
storage (cache miss volume) under concurrent streams.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.pages import Database, Page, PageId
from repro.core.policies.base import BufferPool, Policy
from repro.core.policies.lru import LRUPolicy
from repro.core.policies.opt import OraclePolicy
from repro.core.policies.pbm import PBMPolicy
from repro.core.scans import ScanSpec, ScanState

from .dataset import PAGE_TOKENS, DatasetSpec, generate_page, make_dataset_db


def make_policy(name: str) -> Policy:
    return {
        "lru": LRUPolicy,
        "pbm": PBMPolicy,
        "opt": OraclePolicy,
    }[name]()


class HostPageCache:
    """Capacity-bounded page cache front-ending slow shard storage."""

    def __init__(
        self,
        spec: DatasetSpec,
        capacity_pages: int,
        policy: str = "pbm",
        clock: Optional[Callable[[], float]] = None,
    ) -> None:
        self.spec = spec
        self.db = make_dataset_db(spec)
        self.table = self.db.tables[spec.name]
        self.pool = BufferPool(
            capacity_bytes=capacity_pages * PAGE_TOKENS * 4
        )
        self.policy = make_policy(policy)
        self._clock = clock or time.monotonic
        self._t0 = self._clock()
        self.policy.attach(self.pool, 0.0)
        self._data: Dict[PageId, np.ndarray] = {}   # resident page payloads
        self.miss_pages = 0
        self.hit_pages = 0
        self._scans: Dict[int, ScanState] = {}

    def _now(self) -> float:
        return self._clock() - self._t0

    # ---- stream lifecycle (paper Fig. 3 API) --------------------------------
    def register_stream(
        self, shard_order: List[int], start_page: int = 0, end_page: Optional[int] = None
    ) -> int:
        """A stream discloses its full page plan: shards in order, pages
        sequential within each shard.  Returns a stream id."""
        end = end_page if end_page is not None else self.spec.pages_per_shard
        ranges = []
        cols = tuple(f"shard{s}" for s in shard_order)
        # one ScanState per shard keeps plans sequential per column; we fold
        # them into a single virtual scan over concatenated shard ranges.
        lo = start_page * PAGE_TOKENS
        hi = end * PAGE_TOKENS
        spec = ScanSpec(
            table=self.spec.name,
            columns=cols,
            ranges=((lo, hi),),
            tuple_rate=1.0,
        )
        scan = ScanState(spec, self.db)
        self._scans[scan.scan_id] = scan
        self.policy.register_scan(scan, self._now())
        return scan.scan_id

    def unregister_stream(self, stream_id: int) -> None:
        scan = self._scans.pop(stream_id, None)
        if scan is not None:
            self.policy.unregister_scan(scan, self._now())

    def report_position(self, stream_id: int, tokens_consumed: int) -> None:
        scan = self._scans.get(stream_id)
        if scan is None:
            return
        scan.virt_pos = tokens_consumed * len(scan.spec.columns)
        scan.report_position(self._now())
        self.policy.report_position(scan, self._now())

    # ---- the read path -------------------------------------------------------
    def get_page(self, stream_id: int, shard: int, page: int) -> np.ndarray:
        col = self.table.columns[f"shard{shard}"]
        pobj = col.pages[page]
        now = self._now()
        if self.pool.is_resident(pobj):
            self.hit_pages += 1
        else:
            self.miss_pages += 1
            need = pobj.size_bytes
            if self.pool.free_bytes < need:
                victims = self.policy.choose_victims(need, set(), now)
                for v in victims:
                    self.pool.evict(v)
                    self._data.pop(v.pid, None)
            self.pool.admit(pobj)
            self._data[pobj.pid] = generate_page(self.spec, shard, page)
            self.policy.on_loaded(pobj, now)
        scan = self._scans.get(stream_id)
        if scan is not None:
            self.policy.on_consumed(scan, pobj, now)
        return self._data[pobj.pid]

    @property
    def miss_bytes(self) -> int:
        return self.miss_pages * PAGE_TOKENS * 4
