"""Sharded token datasets over paged storage.

A dataset is a set of shards; each shard is a sequence of fixed-size token
pages materialised on demand from a deterministic generator (offline
container: no external corpora — the generator is a keyed hash so any page
is reproducible from (shard, page) alone, which is also what makes restore-
after-failure trivial: a data position is just (shard, page, offset)).

The storage geometry reuses ``repro.core.pages``: one table per dataset,
one column per shard — so the paper's policies (PBM/LRU/OPT) manage the
host page cache untouched (DESIGN.md §2 mapping: epochs = scans).
"""

from __future__ import annotations

import hashlib
from dataclasses import dataclass
from typing import Iterator, List, Optional, Tuple

import numpy as np

from repro.core.pages import Database, Page, Table

PAGE_TOKENS = 32_768          # tokens per storage page
TOKEN_BYTES = 4


@dataclass(frozen=True)
class DatasetSpec:
    name: str = "synthetic"
    n_shards: int = 16
    pages_per_shard: int = 64
    vocab_size: int = 50_304
    seed: int = 0

    @property
    def tokens_per_shard(self) -> int:
        return self.pages_per_shard * PAGE_TOKENS

    @property
    def total_tokens(self) -> int:
        return self.n_shards * self.tokens_per_shard


def make_dataset_db(spec: DatasetSpec) -> Database:
    """Storage-geometry view: one column per shard, PAGE_TOKENS*4B pages."""
    db = Database()
    db.add_table(
        spec.name,
        n_tuples=spec.tokens_per_shard,
        columns={f"shard{s}": float(TOKEN_BYTES) for s in range(spec.n_shards)},
        chunk_tuples=PAGE_TOKENS * 4,
        page_bytes=PAGE_TOKENS * TOKEN_BYTES,
    )
    return db


def generate_page(spec: DatasetSpec, shard: int, page: int) -> np.ndarray:
    """Deterministic 'disk read': tokens for (shard, page) from a keyed hash.

    Zipf-ish marginal over the vocab so losses behave like text, cheap to
    produce, identical across restarts (fault-tolerant data position).
    """
    key = f"{spec.name}/{spec.seed}/{shard}/{page}".encode()
    seed = int.from_bytes(hashlib.blake2b(key, digest_size=8).digest(), "little")
    rng = np.random.default_rng(seed)
    u = rng.random(PAGE_TOKENS)
    # inverse-CDF of a truncated zipf(1.1)
    ranks = ((u ** -2.0) - 1.0)
    toks = np.clip(ranks.astype(np.int64), 0, spec.vocab_size - 1)
    return toks.astype(np.int32)


def page_of(spec: DatasetSpec, token_pos: int) -> Tuple[int, int, int]:
    """Global token position -> (shard, page, offset)."""
    shard = token_pos // spec.tokens_per_shard
    rem = token_pos % spec.tokens_per_shard
    return shard, rem // PAGE_TOKENS, rem % PAGE_TOKENS
