"""Quickstart: the paper's result in 30 seconds, then the framework around it.

  PYTHONPATH=src python examples/quickstart.py
"""

import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import EngineConfig, run_workload
from repro.core.workload import (
    make_lineitem_db, micro_accessed_bytes, micro_streams,
)


def demo_concurrent_scans():
    print("=== 1. Concurrent scans: LRU vs CScans vs PBM vs OPT (paper) ===")
    db = make_lineitem_db(scale_tuples=18_000_000, page_bytes=64 << 10)
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=8, queries_per_stream=8, seed=3)
    print(f"working set {ws/1e6:.0f}MB, buffer 40%, 700MB/s, 8 streams x 8 queries")
    for pol in ("lru", "cscan", "pbm", "opt"):
        cfg = EngineConfig(bandwidth=700e6, buffer_bytes=int(0.4 * ws),
                           pbm_time_slice=0.01)
        r = run_workload(db, streams, pol, cfg)
        print(f"  {pol:6s} avg stream {r.avg_stream_time:6.2f}s   "
              f"I/O {r.io_gb:5.2f}GB")


def demo_train():
    print("\n=== 2. Train a small LM through the framework ===")
    from repro.configs import get_config
    from repro.models import build_model, init_params
    from repro.train.optimizer import OptimizerConfig, init_opt_state
    from repro.train.train_step import make_train_step
    import numpy as np

    cfg = get_config("qwen2_1_5b", smoke=True)
    model = build_model(cfg)
    params = init_params(model.param_specs, jax.random.PRNGKey(0), jnp.float32)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(model, OptimizerConfig(
        learning_rate=3e-3, warmup_steps=2, total_steps=20)))
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 128, (4, 65)),
                       jnp.int32)
    for i in range(10):
        params, opt, m = step(params, opt, {"tokens": toks[:, :-1]})
        if i % 3 == 0:
            print(f"  step {i} loss {float(m['loss']):.4f}")


def demo_serving():
    print("\n=== 3. Paged-KV serving with PBM preemption ===")
    from repro.serving import PagePool, Request, ServingEngine

    pool = PagePool(n_pages=40, page_size=16, page_bytes=32 << 10)
    eng = ServingEngine(pool, lambda reqs: [42] * len(reqs), policy="pbm")
    common = list(range(32))  # shared system prompt
    for i in range(10):
        eng.submit(Request(prompt=common + [100 + i], max_new_tokens=24))
    st = eng.run_to_completion()
    print(f"  {len(eng.finished)} requests in {st.steps} steps; "
          f"{st.shared_prefix_pages} prefix pages shared; "
          f"{st.preemptions} preemptions; "
          f"swap {(st.swap_out_bytes + st.swap_in_bytes)/1e6:.1f}MB")


if __name__ == "__main__":
    demo_concurrent_scans()
    demo_train()
    demo_serving()
    print("\nSee examples/concurrent_scans_demo.py, examples/train_lm.py, "
          "examples/serve_paged.py for the full drivers.")
