"""The paper end-to-end: all seven policies on the microbenchmark + the
sharing-potential analysis (Figs 11/17 in miniature).

  PYTHONPATH=src python examples/concurrent_scans_demo.py [--scale 0.1]
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.core import EngineConfig, run_workload, simulate_belady
from repro.core.policy_registry import names as policy_names
from repro.core.stats import sharing_potential
from repro.core.workload import (
    make_lineitem_db, micro_accessed_bytes, micro_streams,
)

POLICIES = policy_names(backend="event")  # all seven, registry order


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scale", type=float, default=0.1,
                    help="fraction of SF30 lineitem (1.0 = paper scale)")
    ap.add_argument("--buffer", type=float, default=0.4)
    ap.add_argument("--streams", type=int, default=8)
    args = ap.parse_args()

    db = make_lineitem_db(scale_tuples=int(180e6 * args.scale),
                          page_bytes=max(16 << 10, int(512 << 10 * args.scale)))
    ws = micro_accessed_bytes(db)
    streams = micro_streams(db, n_streams=args.streams, queries_per_stream=16,
                            seed=3)
    print(f"lineitem scale={args.scale:.2f}: working set {ws/1e6:.0f}MB, "
          f"buffer {args.buffer:.0%}, {args.streams} streams x 16 queries\n")
    print(f"{'policy':10s} {'avg stream (s)':>15s} {'total I/O (GB)':>15s}")
    pbm_run = None
    for pol in POLICIES:
        cfg = EngineConfig(
            bandwidth=700e6, buffer_bytes=int(args.buffer * ws),
            record_trace=(pol == "pbm"), pbm_time_slice=0.1 * args.scale,
        )
        r = run_workload(db, streams, pol, cfg)
        star = {"pbm": "  <- the paper's contribution",
                "pbm_lru": "  <- paper future-work, built",
                "attach": "  <- paper future-work, built"}.get(pol, "")
        print(f"{pol:10s} {r.avg_stream_time:15.2f} {r.io_gb:15.2f}{star}")
        if pol == "pbm":
            pbm_run = r
    # paper's OPT methodology: Belady on the PBM trace
    _, belady_bytes = simulate_belady(
        pbm_run.trace, page_sizes=pbm_run.page_sizes,
        capacity_bytes=int(args.buffer * ws))
    print(f"{'opt(trace)':10s} {'-':>15s} {belady_bytes/1e9:15.2f}"
          f"  <- Belady on PBM reference trace")
    sp = sharing_potential(pbm_run)
    print(f"\nsharing potential: {sp.reusable_fraction:.0%} of in-demand bytes "
          f"wanted by >=2 scans (paper Fig 17)")


if __name__ == "__main__":
    main()
