"""Serving through the full paged stack: real attention over policy-managed
KV pages.

PagedTinyLM computes every decode step with ``kernels.paged_attention``
(interpret mode on CPU, Mosaic on TPU) reading K/V through the page tables
that the ServingEngine + PagePool manage: prefix sharing, PBM preemption,
host spill — the kernel never sees a contiguous cache.

  PYTHONPATH=src python examples/serve_paged.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.kernels import ops
from repro.serving import PagePool, Request, ServingEngine
from repro.serving.model import PagedTinyLM, TinyConfig


def main():
    ops.set_backend("interpret")  # execute the Pallas kernel body on CPU
    cfg = TinyConfig(n_pages=96, page_size=16)
    lm = PagedTinyLM(cfg, seed=0)
    pool = PagePool(n_pages=cfg.n_pages, page_size=cfg.page_size,
                    page_bytes=cfg.page_size * cfg.n_kv_heads * cfg.head_dim * 2 * 2)
    eng = ServingEngine(pool, lm.step_fn, policy="pbm", max_batch=4)

    rng = np.random.default_rng(0)
    system_prompt = list(rng.integers(0, cfg.vocab, 32))  # 2 shared pages
    for _ in range(6):
        eng.submit(Request(
            prompt=system_prompt + list(rng.integers(0, cfg.vocab, 4)),
            max_new_tokens=8,
        ))
    st = eng.run_to_completion(max_steps=500)
    print(f"served {len(eng.finished)} requests in {st.steps} engine steps")
    print(f"prefix pages shared: {st.shared_prefix_pages}  "
          f"preemptions: {st.preemptions}")
    for r in eng.finished[:3]:
        print(f"  req {r.rid}: generated {r.generated}")
    # determinism check: same prompts, same tokens
    lm2 = PagedTinyLM(cfg, seed=0)
    pool2 = PagePool(n_pages=cfg.n_pages, page_size=cfg.page_size,
                     page_bytes=pool.page_bytes)
    eng2 = ServingEngine(pool2, lm2.step_fn, policy="opt", max_batch=4)
    rng = np.random.default_rng(0)
    system_prompt = list(rng.integers(0, cfg.vocab, 32))
    for _ in range(6):
        eng2.submit(Request(
            prompt=system_prompt + list(rng.integers(0, cfg.vocab, 4)),
            max_new_tokens=8,
        ))
    eng2.run_to_completion(max_steps=500)
    same = all(
        a.generated == b.generated
        for a, b in zip(
            sorted(eng.finished, key=lambda r: r.rid),
            sorted(eng2.finished, key=lambda r: r.rid),
        )
    )
    print(f"tokens identical under a different eviction policy: {same} "
          f"(paging must never change results)")


if __name__ == "__main__":
    main()
