"""End-to-end training driver example.

CPU demo (~2M params, a few hundred steps, PBM-cached data pipeline,
checkpoint + exact resume):

  PYTHONPATH=src python examples/train_lm.py --steps 200

This wraps ``repro.launch.train``; on a pod you would run the same module
with a full config (see launch/train.py docstring).  The documented target
configuration for the deliverable is a ~100M-param qwen2-family model for a
few hundred steps — pass ``--preset 100m`` on real hardware; the default
preset is CPU-sized so the example completes in minutes.
"""

import subprocess
import sys

PRESETS = {
    "cpu": ["--arch", "qwen2_1_5b", "--smoke", "--batch", "8", "--seq", "256"],
    # ~100M params: full qwen2 width, depth 4 — runnable on one accelerator
    "100m": ["--arch", "qwen2_1_5b", "--batch", "32", "--seq", "1024",
             "--microbatches", "4"],
}

if __name__ == "__main__":
    args = sys.argv[1:]
    preset = "cpu"
    if "--preset" in args:
        i = args.index("--preset")
        preset = args[i + 1]
        args = args[:i] + args[i + 2:]
    if "--steps" not in args:
        args += ["--steps", "200"]
    if "--checkpoint-dir" not in args:
        args += ["--checkpoint-dir", "/tmp/repro_ckpt"]
    cmd = [sys.executable, "-m", "repro.launch.train"] + PRESETS[preset] + args
    print("+", " ".join(cmd))
    raise SystemExit(subprocess.call(cmd, env={
        **__import__("os").environ, "PYTHONPATH": "src"
    }))
